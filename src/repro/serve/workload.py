"""ServeWorkload: the EROICA loop over the REAL jax serving engine
(DESIGN.md §13).

The third ``WorkloadSource``: each fleet worker runs a real
continuous-batched decode loop — the same jit'd ``make_serve_step`` the
``Engine`` serves with, fenced with ``block_until_ready`` — under a
seeded Poisson request generator with configurable burst phases
(``RequestGen``).  Anchors are request dequeue -> completion pairs;
profiles come from the ``Tracer`` + per-process ``ProcessSampler`` path
(dequeue wait as a PYTHON frame, decode steps as fenced GPU spans, KV
block reads as MEM spans); the ``slo`` metrics stream carries per-request
(t, p99_ttft, p99_tbt) samples merged across workers (worst per index —
the user-visible tail is the slowest replica).

Continuous-batching-lite: one global KV position cursor per worker —
requests append to the live cache back-to-back and the cache resets only
when the cursor would overrun ``max_len`` — so decode never pays a
per-request cache re-init, the property continuous batching exists to
buy.

Live faults perturb the REAL loop (no synthesis anywhere), magnitudes
relative to the worker's measured healthy request/token times:

  * ``BurstArrivals`` — multiply the generator's arrival rate: the
    backlog model makes dequeue waits grow window over window (queue
    buildup), blowing p99 TTFT while decode stays healthy;
  * ``DecodeStall``  — stall inside the fenced decode step on a worker
    subset (hot/throttled decode device): p99 TBT blows on those hosts;
  * ``CacheThrash``  — every token pays a KV block read stall (working
    set exceeding device memory): fleet-wide TBT + MEM-frame stretch.
"""
from __future__ import annotations

import contextlib
import os
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import Kind
from repro.online.workload import (WindowData, WorkloadSource,
                                   merge_anchor_durations)

#: fraction of the request span at which the completion anchor lands
#: (mirrors ``_OPT_ANCHOR_FRAC``; the serve anchor names never lock the
#: perf iteration detector — SLO incidents open on the ``slo`` channel)
_COMPLETE_ANCHOR_FRAC = 0.97

#: tracer function names (what localization reports; the serving playbook
#: and ``root_cause_hint`` key on the generic queue/kv/decode patterns)
QUEUE_WAIT = "serve.queue:dequeue_wait"
DECODE_STEP = "decode.step"
KV_READ = "kv_cache.read_block"

#: dequeue/admission frame shape: a poll of ``_POLL_FRAC`` x service per
#: request, plus scheduler work growing with the backlog (queue scans /
#: batch formation) once the queueing delay exceeds the half-service
#: slack a healthy queue rides at
_POLL_FRAC = 0.005
_SCHED_BACKLOG_FRAC = 0.15


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def tiny_serve_setup():
    """Smoke-scale real-serving configs (a shrunk ``gemma2-2b``), sized by
    env knobs so CI runners can shrink further:

      REPRO_SERVE_ARCH / REPRO_SERVE_LAYERS / REPRO_SERVE_D_MODEL /
      REPRO_SERVE_VOCAB / REPRO_SERVE_BATCH / REPRO_SERVE_MAX_LEN /
      REPRO_SERVE_PROMPT / REPRO_SERVE_NEW_TOKENS

    Returns ``(model_cfg, serve_cfg, prompt_len, n_new)``."""
    from repro.configs.registry import ARCHS, reduced
    from repro.serve.engine import ServeConfig
    arch = os.environ.get("REPRO_SERVE_ARCH", "gemma2-2b")
    cfg = reduced(ARCHS[arch],
                  layers=_env_int("REPRO_SERVE_LAYERS", 2),
                  d_model=_env_int("REPRO_SERVE_D_MODEL", 32),
                  vocab=_env_int("REPRO_SERVE_VOCAB", 256))
    sc = ServeConfig(batch=_env_int("REPRO_SERVE_BATCH", 2),
                     max_len=_env_int("REPRO_SERVE_MAX_LEN", 128))
    return (cfg, sc, _env_int("REPRO_SERVE_PROMPT", 4),
            _env_int("REPRO_SERVE_NEW_TOKENS", 8))


class RequestGen:
    """Seeded Poisson request arrivals with burst phases.

    ``delay(service_s)`` advances one request through an M/D/1-lite
    backlog on a VIRTUAL timeline: exponential inter-arrival gaps at
    ``rate_rps * burst_mult`` against a single server busy for
    ``service_s`` per request.  It returns the request's queueing delay —
    how long it sat in the queue before the server picked it up.  At
    utilization < 1 delays stay small; a burst phase (``burst_mult``
    pushing utilization past 1) makes the backlog — and every later
    request's delay — GROW window over window, which is what "queue
    buildup" means.  State persists across windows; delays are capped so
    an injected burst degrades the loop detectably, not unboundedly.
    Given a fixed seed and constant ``service_s`` the delay sequence is
    fully deterministic."""

    def __init__(self, rate_rps: float, seed: int = 0,
                 max_delay_s: Optional[float] = None):
        self.rate_rps = float(rate_rps)
        self.burst_mult = 1.0
        self.max_delay_s = max_delay_s
        self._rng = np.random.default_rng((int(seed), 0x5E17E))
        self._clock = 0.0            # last arrival time (virtual)
        self._free_at = 0.0          # server free time (virtual)

    def delay(self, service_s: float) -> float:
        """Queueing delay of the next request given its service time."""
        gap = self._rng.exponential(
            1.0 / max(1e-9, self.rate_rps * self.burst_mult))
        self._clock += gap
        start = max(self._clock, self._free_at)
        self._free_at = start + float(service_s)
        d = start - self._clock
        if self.max_delay_s is not None:
            d = min(d, self.max_delay_s)
        return d


# -- live faults --------------------------------------------------------------

@dataclass(frozen=True)
class ServeFault:
    """A perturbation of the real serving loop on a worker subset."""
    workers: Tuple[int, ...]

    def apply(self, worker: "_ServeWorker") -> None:
        raise NotImplementedError


@dataclass(frozen=True)
class BurstArrivals(ServeFault):
    """Arrival-rate burst: the generator's rate multiplies, the backlog
    grows, dequeue waits (and p99 TTFT) explode while decode stays
    healthy."""
    factor: float = 8.0

    def apply(self, worker: "_ServeWorker") -> None:
        worker.gen.burst_mult = float(self.factor)


@dataclass(frozen=True)
class DecodeStall(ServeFault):
    """Stall inside the fenced decode step (hot/throttled decode device):
    each token stretches to ~``factor`` x the measured healthy TBT."""
    factor: float = 4.0
    pad_s: float = 0.0

    def apply(self, worker: "_ServeWorker") -> None:
        worker.decode_pad_s = \
            self.pad_s or max(0.0, self.factor - 1.0) * worker.base_tbt_s


@dataclass(frozen=True)
class CacheThrash(ServeFault):
    """Every token pays a KV block read stall (working set exceeds
    device memory): TBT stretches and the MEM frame dominates."""
    factor: float = 4.0
    stall_s: float = 0.0

    def apply(self, worker: "_ServeWorker") -> None:
        worker.kv_stall_s = \
            self.stall_s or self.factor * worker.base_tbt_s


def _install_faults(workers: Sequence["_ServeWorker"],
                    faults: Sequence[ServeFault]) -> None:
    for sw in workers:
        sw.clear_faults()
    for f in faults or []:
        for sw in workers:
            if not f.workers or sw.worker in f.workers:
                f.apply(sw)


# -- one real serving worker --------------------------------------------------

class _ServeWorker:
    """One fleet worker: a real jit'd decode loop + its ``Tracer``."""

    def __init__(self, worker: int, model_cfg, serve_cfg, prompt_len: int,
                 n_new: int, rate_hz: float = 1000.0, params=None):
        import jax
        from repro.instrument.tracer import ProcessSampler, Tracer
        from repro.models.transformer import Transformer
        from repro.train.step import make_serve_step
        self.worker = int(worker)
        self.cfg, self.sc = model_cfg, serve_cfg
        self.prompt_len, self.n_new = int(prompt_len), int(n_new)
        self.model = Transformer(model_cfg)
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(0)))
        self._step = jax.jit(make_serve_step(self.model))
        # on a CPU-jit host there IS no gpu_sm/membw sampler: route the
        # decode/KV frames to the honest cpu stream (same convention as
        # ``Trainer._step_resource``), keeping their Kinds for the boxes
        self._res = "cpu" if jax.default_backend() == "cpu" else ""
        # per-process CPU: a dequeue wait in THIS worker reads mu~0 even
        # on a busy shared host (the queue hint's non-CPU-intensive rule)
        self.tracer = Tracer(worker=self.worker, samplers={
            "cpu": ProcessSampler(rate_hz=rate_hz)})
        self._prompt_rng = np.random.default_rng((self.worker, 0x9E3))
        # continuous-batching-lite: cache + global position cursor persist
        # across requests, reset only at max_len overrun
        self.cache = None
        self.pos = 0
        self.gen: Optional[RequestGen] = None
        self.base_tbt_s = 0.0
        self.base_request_s = 0.0
        self.clear_faults()

    def clear_faults(self) -> None:
        self.decode_pad_s = 0.0
        self.kv_stall_s = 0.0
        if self.gen is not None:
            self.gen.burst_mult = 1.0

    def _decode_tokens(self, tracer=None) -> List[float]:
        """One request through the live cache: ``prompt_len`` prefill +
        ``n_new`` decode tokens, token-by-token on the global position
        cursor, fencing every step.  Tokens run inside long fenced
        ``decode.step`` spans (per-token frames would be shorter than the
        cpu sampling period, erasing the mu contrast localization keys
        on).  KV stalls must be depth-1 MEM frames BETWEEN those spans,
        not nested inside: the critical path hands every segment to the
        highest-priority covering event (GPU beats MEM), so a MEM frame
        inside a GPU span can never earn beta.  Under a thrash fault the
        token loop therefore splits into chunks, each chunk's tokens
        paying one aggregated ``kv_cache.read_block`` frame — the
        mid-request one still lands BETWEEN token completions, which is
        the TBT signal.  Returns the per-generated-token completion times
        (perf_counter)."""
        import jax.numpy as jnp
        steps = self.prompt_len + self.n_new - 1
        if self.cache is None or self.pos + steps > self.sc.max_len:
            self.cache = self.model.init_cache(self.sc.batch,
                                               self.sc.max_len)
            self.pos = 0
        prompt = self._prompt_rng.integers(
            0, self.cfg.vocab_size,
            (self.sc.batch, self.prompt_len)).astype(np.int32)
        nxt = None
        done: List[float] = []
        chunk = (steps + 1) // 2 if self.kv_stall_s else steps
        lo = 0
        while lo < steps:
            hi = min(steps, lo + chunk)
            span = (tracer.phase(DECODE_STEP, Kind.GPU, depth=1,
                                 resource=self._res)
                    if tracer else contextlib.nullcontext())
            with span:
                for t in range(lo, hi):
                    cur = (jnp.asarray(prompt[:, t])[:, None]
                           if t < self.prompt_len else nxt[:, None])
                    logits, self.cache = self._step(
                        self.params, self.cache, {"tokens": cur},
                        jnp.int32(self.pos))
                    nxt = jnp.argmax(
                        logits[:, 0, :self.cfg.vocab_size], axis=-1)
                    nxt.block_until_ready()
                    if self.decode_pad_s:
                        time.sleep(self.decode_pad_s)
                    self.pos += 1
                    if t >= self.prompt_len - 1:
                        done.append(time.perf_counter())
            if self.kv_stall_s:
                stall = self.kv_stall_s * (hi - lo)
                if tracer:
                    with tracer.phase(KV_READ, Kind.MEM, depth=1,
                                      resource=self._res):
                        time.sleep(stall)
                else:
                    time.sleep(stall)
            lo = hi
        return done

    def serve_request(self, tracer=None) -> Tuple[float, float, float]:
        """Dequeue + serve one request; returns (duration_s, ttft_s,
        p99_tbt_s).

        The generator's queueing delay is VIRTUAL (the synthetic arrival
        timeline): it counts toward TTFT — the user waited that long —
        but the server does not sleep it (while a request queues, the
        server is busy with earlier ones).  What the server DOES pay is
        the dequeue/admission frame: a small poll plus scheduler work
        that grows with the backlog (batch formation scans the queue), so
        under a burst the PYTHON ``dequeue_wait`` frame is what
        localization sees stretch.  TBT percentiles come from the
        request's own measured token intervals."""
        service = self.base_request_s or 1e-3
        qd = self.gen.delay(service) if self.gen is not None else 0.0
        sched = (_POLL_FRAC * service
                 + _SCHED_BACKLOG_FRAC * max(0.0, qd - 0.5 * service))
        t_deq = time.perf_counter()
        if tracer:
            with tracer.phase(QUEUE_WAIT, Kind.PYTHON, depth=1):
                time.sleep(sched)
        else:
            time.sleep(sched)
        done = self._decode_tokens(tracer=tracer)
        t_end = time.perf_counter()
        ttft = qd + (done[0] - t_deq)
        gaps = np.diff(done)
        tbt = float(np.percentile(gaps, 99)) if len(gaps) else ttft
        return t_end - t_deq, ttft, tbt

    def warmup(self, requests: int = 3):
        """Compile (first request) + measure the healthy baselines (tracer
        inactive, generator off).  Returns ``params`` so same-shape
        siblings can share the compiled program's weights structure."""
        durs, tbts = [], []
        for _ in range(max(2, requests)):
            dur, _, tbt = self.serve_request(tracer=None)
            durs.append(dur)
            tbts.append(tbt)
        self.base_request_s = float(np.median(durs[1:]))  # drop compile
        self.base_tbt_s = float(np.median(tbts[1:]))
        return self.params

    def run_window(self, requests: int, rate: Optional[float] = None):
        """One profiling window of ``requests`` requests.

        Returns (durations, WorkerProfile); side effect:
        ``self.window_slo`` holds the window's per-request (ttft, tbt)
        pairs — the slo channel's raw material."""
        if rate is not None:
            self.tracer.set_rate(float(rate))
        self.tracer.start_window()
        durs: List[float] = []
        self.window_slo: List[Tuple[float, float]] = []
        for _ in range(requests):
            dur, ttft, tbt = self.serve_request(tracer=self.tracer)
            durs.append(dur)
            self.window_slo.append((ttft, tbt))
        return durs, self.tracer.stop_window()

    def close(self) -> None:
        self.cache = None


# -- merging ------------------------------------------------------------------

def merge_slo_samples(per_worker: Sequence[Sequence[Tuple[float, float]]],
                      durations: Sequence[float], t0: float
                      ) -> List[Tuple[float, float, float]]:
    """Job-level (t, p99_ttft, p99_tbt) samples from per-worker
    per-request (ttft, tbt) pairs: worst (max) per request index — the
    user-visible tail latency is the slowest replica's.  Timestamps chain
    the merged request ``durations`` on the job clock from ``t0`` (same
    clock as the anchors)."""
    n = max((len(d) for d in per_worker), default=0)
    out: List[Tuple[float, float, float]] = []
    t = float(t0)
    for i in range(n):
        t += float(durations[i]) if i < len(durations) else 0.0
        pairs = [d[i] for d in per_worker if i < len(d)]
        out.append((t, max(float(p[0]) for p in pairs),
                    max(float(p[1]) for p in pairs)))
    return out


def synth_serve_anchors(durations: Sequence[float], t0: float
                        ) -> Tuple[List[Tuple[str, float]], float]:
    """(dequeue, complete) anchor pairs for merged request durations,
    chained on a continuous clock from ``t0``."""
    out: List[Tuple[str, float]] = []
    t = float(t0)
    for dur in durations:
        out.append(("request.dequeue", t))
        out.append(("request.complete", t + dur * _COMPLETE_ANCHOR_FRAC))
        t += dur
    return out, t


# -- the in-process workload --------------------------------------------------

class ServeWorkload(WorkloadSource):
    """Real-serving profile source for ``ScenarioRunner``.

    Workers build lazily on the first window; all share ONE set of
    initialized params (identical configs).  Windows run each worker
    SEQUENTIALLY — ``ProcessSampler`` is per-process, so one-at-a-time
    keeps every cpu sample attributable to the worker being profiled
    (same contract as ``TrainerWorkload``).  ``utilization`` sets the
    generators' healthy arrival rate as a fraction of each worker's
    measured service rate (< 1 = slack; a ``BurstArrivals`` fault pushes
    it past 1)."""

    @property
    def family(self) -> str:
        return "host"

    @property
    def channel(self) -> str:
        """Profile abnormalities under a serving workload belong to the
        latency-SLO channel (DESIGN.md §13)."""
        return "slo"

    def __init__(self, n_workers: int = 2, setup=None,
                 rate_hz: float = 1000.0, warmup_requests: int = 3,
                 utilization: float = 0.3, seed: int = 0,
                 max_delay_factor: float = 6.0):
        self.n = int(n_workers)
        self.cfgs = setup if setup is not None else tiny_serve_setup()
        self.rate_hz = float(rate_hz)
        self.warmup_requests = int(warmup_requests)
        self.utilization = float(utilization)
        self.seed = int(seed)
        self.max_delay_factor = float(max_delay_factor)
        self.workers: List[_ServeWorker] = []
        self._clock = 0.0

    @property
    def total_workers(self) -> int:
        return self.n

    @property
    def active_workers(self) -> np.ndarray:
        return np.arange(self.n)

    def _ensure_workers(self) -> None:
        if self.workers:
            return
        mc, sc, prompt_len, n_new = self.cfgs
        params = None
        for w in range(self.n):
            sw = _ServeWorker(w, mc, sc, prompt_len, n_new,
                              rate_hz=self.rate_hz, params=params)
            params = sw.warmup(self.warmup_requests)
            sw.gen = RequestGen(
                rate_rps=self.utilization / max(1e-9, sw.base_request_s),
                seed=self.seed + w,
                max_delay_s=self.max_delay_factor * sw.base_request_s)
            self.workers.append(sw)

    @property
    def base_request_s(self) -> float:
        self._ensure_workers()
        return float(np.median([sw.base_request_s for sw in self.workers]))

    def run_window(self, window: int, faults: Sequence, iters: int,
                   rates: Optional[np.ndarray]) -> WindowData:
        self._ensure_workers()
        _install_faults(self.workers, faults)
        t0 = self._clock
        per_durs, per_slo, profiles = [], [], []
        for sw in self.workers:      # sequential: per-worker cpu streams
            r = None if rates is None else float(rates[sw.worker])
            durs, prof = sw.run_window(iters, rate=r)
            per_durs.append(durs)
            per_slo.append(sw.window_slo)
            profiles.append(prof)
        merged = merge_anchor_durations(per_durs)
        anchors, self._clock = synth_serve_anchors(merged, t0)
        return WindowData(anchors=anchors, profiles=profiles,
                          workers=np.arange(self.n), clock=self._clock,
                          t0=t0, metrics={"slo": merge_slo_samples(
                              per_slo, merged, t0)})

    def close(self) -> None:
        for sw in self.workers:
            sw.close()
        self.workers = []
