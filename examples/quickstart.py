"""Quickstart: train a tiny LM with PerfTracker attached, inject a storage
fault mid-run, watch the online diagnosis fire (paper case C2P1, live).

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.registry import ARCHS, reduced
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import TrainConfig, Trainer


def main():
    cfg = reduced(ARCHS["gemma2-2b"], d_model=64, vocab=256)
    trainer = Trainer(
        cfg,
        DataConfig(batch=4, seq_len=32),
        OptConfig(lr_peak=5e-3, warmup_steps=5, total_steps=120),
        TrainConfig(steps=120, log_every=20, perftracker=True,
                    pt_window_s=0.3),
    )
    trainer.pt.service.detector.cfg.n_recent = 10

    # inject the fault at step 60: data loading becomes 20x slower
    orig_next = trainer.loader.next

    def degrading_next():
        if trainer.loader.step == 60:
            print(">>> injecting slow-storage fault (case C2P1)")
            trainer.loader.source.data.delay_s = 0.05
        return orig_next()

    trainer._next, _ = trainer.pt.wrap(degrading_next, lambda: None)
    trainer.run()

    res = trainer.pt.flush()
    if res is None and trainer.pt.results:
        res = trainer.pt.results[-1]
    if res is None:
        # re-armed detector fires once per incident; the window it opened
        # may already have been consumed by mitigation — show that one
        res = trainer.last_diagnosis
    print()
    if trainer.pt.service.detector.triggers:
        t = trainer.pt.service.detector.triggers[0]
        print(f"degradation detected: {t.reason} ({t.detail})")
    if res is not None:
        print(res.report())
    else:
        print("no diagnosis window completed (try more steps)")


if __name__ == "__main__":
    main()
