"""End-to-end training driver: a ~25M-param gemma2-family model on the
synthetic-LM pipeline for a few hundred steps (CPU-sized; pass --arch/--steps
to scale). Loss decreases; checkpoints + PerfTracker online.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.configs.registry import ARCHS, reduced
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch], layers=args.layers,
                  d_model=args.d_model, vocab=args.vocab)
    n = cfg.param_counts()["total"]
    print(f"arch={cfg.name} (reduced) params~{n/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")
    trainer = Trainer(
        cfg,
        DataConfig(batch=args.batch, seq_len=args.seq),
        OptConfig(lr_peak=args.lr, warmup_steps=max(10, args.steps // 20),
                  total_steps=args.steps),
        TrainConfig(steps=args.steps, log_every=max(1, args.steps // 20),
                    ckpt_dir=args.ckpt_dir, ckpt_every=args.steps // 4,
                    perftracker=True),
    )
    trainer.run()
    first = trainer.history[0]["loss"]
    last = trainer.history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")
    print(f"checkpoints: {trainer.ckpt.steps()} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
