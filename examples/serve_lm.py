"""Batched serving example: greedy/temperature decode with KV caches on a
small model; verifies decode==forward consistency and reports tokens/s.

  PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-lite-16b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS, reduced
from repro.models.transformer import Transformer
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-34b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch], d_model=128, layers=4, vocab=512)
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"serving {cfg.name} (reduced, {n/1e6:.1f}M params) "
          f"batch={args.batch}")

    engine = Engine(cfg, params, ServeConfig(
        batch=args.batch, max_len=args.prompt_len + args.new_tokens,
        temperature=args.temperature))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {out[b, :args.prompt_len].tolist()} => "
              f"{out[b, args.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
