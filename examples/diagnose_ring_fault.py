"""Reproduce the paper's §3 ring-communication study (Figs. 3-5): simulate a
32-worker NCCL-style ring fleet, degrade one NIC bond to 50%, summarize each
worker's (beta, mu, sigma) pattern, and localize the slow link.

  PYTHONPATH=src python examples/diagnose_ring_fault.py
"""

from repro.core import faults as F
from repro.core.mitigation import plan_mitigations
from repro.core.service import PerfTrackerService
from repro.core.simulation import ALLGATHER, FleetSimulator, SimConfig


def main():
    slow_worker, rho = 9, 0.5
    sim = FleetSimulator(
        SimConfig(n_workers=32, window_s=2.0, rate_hz=2000, seed=11),
        [F.RingSlowLink(slow_worker=slow_worker, rho=rho)])
    svc = PerfTrackerService()

    trig = svc.feed_anchors(sim.anchor_events(80, degrade_after=40))
    print(f"detector: {trig.reason} — {trig.detail}\n")

    profiles = sim.profile_window()
    res = svc.diagnose_profiles(profiles, trigger=trig)

    # Fig. 5-style view of the collective's per-worker patterns
    from repro.core.daemon import summarize_and_upload
    print(f"{'worker':>6s} {'mu(PCIe)':>9s} {'sigma':>7s}  signature")
    for w in (0, 1, slow_worker, 20, 31):
        pats, _ = summarize_and_upload(profiles[w]).unpack()
        b, m, s = pats[ALLGATHER]
        sig = ("slow link (low, STABLE — Fig. 5c)" if w == slow_worker
               else "waiting on slow link (fluctuating — Fig. 5b)")
        print(f"{w:6d} {m:9.3f} {s:7.3f}  {sig}")

    print()
    print(res.report())
    print()
    for p in plan_mitigations(res.diagnoses, 32):
        print(f"mitigation: {p.action.value} {p.workers} — {p.detail}")


if __name__ == "__main__":
    main()
