"""Online incident pipeline demo (DESIGN.md §7, §8, §9).

A 14-window simulated training run: GPUs on workers 3 and 11 start
throttling at window 2; a slow-storage fault overlaps from window 4; both
clear later.  The fleet profiles at a cheap 250 Hz base rate — only
implicated workers escalate to the full 2 kHz.

Run:  PYTHONPATH=src python examples/online_demo.py
      PYTHONPATH=src python examples/online_demo.py --wire [--loss 0.1]
      PYTHONPATH=src python examples/online_demo.py --mitigate
      PYTHONPATH=src python examples/online_demo.py --scenario E3_bad_standby_driver
      PYTHONPATH=src python examples/online_demo.py --list-scenarios

``--wire`` runs the SAME scenario across real process boundaries: 4
spawned worker processes each run per-worker daemons over their slice of
the fleet and upload ~KB patterns over a Unix socket (DESIGN.md §8);
``--loss`` injects that fraction of upload drops at the framing layer to
show the partial-window degradation story.

``--mitigate`` closes the loop (DESIGN.md §9): the schedule never removes
the faults — instead the MitigationEngine executes each incident's ladder
against the simulator (throttled hosts are replaced by standbys via an
elastic re-mesh, the dataloader migrates), verification watches the
signature clear, and every incident is driven to ``resolved``.

``--scenario <name>`` runs ONE entry of the gated fault-scenario catalog
(DESIGN.md §12) with the mitigation loop closed and scores the outcome
against its declared expectations — try ``E3_bad_standby_driver`` to
watch ``replace_hosts`` land on a poisoned standby and the incident
escalate honestly.  ``--list-scenarios`` prints the catalog.

Serving scenarios (DESIGN.md §13) run the same way — try
``--scenario SV2_arrival_burst`` to watch a latency-SLO incident open on
the ``slo`` channel and resolve through ``shed_load``; for the loop over
the REAL jax serving engine (live arrival-burst / decode-stall /
KV-thrash faults), see ``tests/test_serve_workload.py`` and
``repro/serve/workload.py``.
"""
import argparse

from repro.core import faults as F
from repro.core.simulation import SimConfig
from repro.online import EscalationPolicy, ScenarioRunner, ScheduledFault

W = 24
N_STANDBY = 4
N_WINDOWS = 14


def make_runner(mitigate: bool = False):
    if mitigate:
        # nothing but the engine can clear these faults
        schedule = [
            ScheduledFault(F.GpuThrottle(workers=(3, 11)), start_window=2,
                           end_window=N_WINDOWS),
            ScheduledFault(F.SlowDataloader(), start_window=4,
                           end_window=N_WINDOWS),
        ]
        n_standby = N_STANDBY
    else:
        schedule = [
            ScheduledFault(F.GpuThrottle(workers=(3, 11)), start_window=2,
                           end_window=8),
            ScheduledFault(F.SlowDataloader(), start_window=4,
                           end_window=10),
        ]
        n_standby = 0
    escalation = EscalationPolicy(n_workers=W + n_standby,
                                  base_rate_hz=250.0,
                                  full_rate_hz=2000.0, max_escalated=8)
    runner = ScenarioRunner(
        SimConfig(n_workers=W, window_s=1.0, rate_hz=2000.0, seed=5,
                  n_standby=n_standby),
        schedule, n_windows=N_WINDOWS, escalation=escalation,
        mitigation=mitigate)
    return runner, schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--wire", action="store_true",
                    help="run across 4 real worker processes over the wire "
                         "transport (DESIGN.md §8)")
    ap.add_argument("--loss", type=float, default=0.0,
                    help="with --wire: fraction of upload frames dropped at "
                         "the framing layer")
    ap.add_argument("--mitigate", action="store_true",
                    help="execute mitigation plans against the simulator "
                         "and verify recovery (DESIGN.md §9)")
    ap.add_argument("--scenario", default="",
                    help="run one catalog scenario (DESIGN.md §12) with "
                         "mitigation closed and score it against its "
                         "declared expectations")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print the fault-scenario catalog and exit")
    args = ap.parse_args()
    if args.wire and args.mitigate:
        ap.error("--mitigate is in-process only (cures cannot yet be "
                 "broadcast to spawned daemons)")
    if args.scenario and args.wire:
        ap.error("--scenario is in-process only")

    if args.list_scenarios:
        from repro.online import SCENARIOS
        for sc in SCENARIOS:
            expect = ", ".join(
                f"{e.function.split('/')[-1]}[{e.outcome}]"
                for e in sc.expect)
            print(f"{sc.name:28s} {sc.fault_class:12s} -> {expect}")
        return

    if args.scenario:
        from repro.online import evaluate, run_scenario
        from repro.online.catalog import by_name
        sc = by_name(args.scenario)
        runner, result = run_scenario(sc)
    elif args.wire:
        runner, schedule = make_runner(mitigate=args.mitigate)
        result = runner.run_multiprocess(n_procs=4, loss=args.loss)
    else:
        runner, schedule = make_runner(mitigate=args.mitigate)
        result = runner.run()

    print("=== per-window reports " + "=" * 40)
    for rep in result.reports:
        faults = [type(f).__name__ for f in runner.faults_at(rep.index)]
        print(f"\n-- window {rep.index:2d}  t={rep.t:7.1f}s  "
              f"faults={faults or ['-']}  escalated={rep.escalated or '-'}  "
              f"raw={rep.raw_bytes / 1e6:.1f}MB")
        for m in rep.mitigations:
            print(f"   ENGINE: {m}")
        print(rep.report(W))

    wire = result.wire_summary()
    if wire is not None:
        print("\n=== wire transport " + "=" * 44)
        print(f"uploads delivered: {wire['delivered']}/{wire['expected']}  "
              f"partial windows: {wire['partial_windows']}  "
              f"duplicates: {wire['duplicates']}  "
              f"client-side drops: {wire['client_dropped']}")

    print("\n=== incident timeline " + "=" * 41)
    print(result.timeline())

    if args.mitigate or args.scenario:
        print("\n=== fleet after mitigation " + "=" * 36)
        active = runner.sim.active_workers
        print(f"active workers ({len(active)}): {active}")
        print(f"standbys left: {runner.sim.standbys}")

    if args.scenario:
        print("\n=== scorecard " + "=" * 49)
        for row in evaluate(sc, runner, result):
            outcome = ("resolved" if row["resolved"]
                       else "escalated" if row["escalated"] else "MISSING")
            print(f"{'OK ' if row['ok'] else 'FAIL'} "
                  f"{row['function'][:40]:40s} ch={row['channel']:8s} "
                  f"{outcome:9s} first={row['first_action']} "
                  f"escalations={row['escalations']} wtr={row['wtr']}")

    print("\n=== cost " + "=" * 54)
    total = sum(r.raw_bytes for r in result.reports)
    full = len(result.reports) * W * 1.0 * 2000.0 * 4 * 8
    print(f"bytes profiled: {total / 1e6:.1f} MB "
          f"(always-full-rate would be ~{full / 1e6:.1f} MB -> "
          f"{full / total:.1f}x saved by differential escalation)")
    for inc in result.incidents:
        ow = result.window_of(inc.opened_at)
        rw = (result.window_of(inc.resolved_at)
              if inc.resolved_at is not None else None)
        print(f"incident #{inc.id}: {inc.function[:44]} [{inc.state}] "
              f"windows {ow}->{rw} workers={list(inc.workers)[:8]}")


# the __main__ guard is load-bearing for --wire: the multiprocessing spawn
# context re-imports this script in every worker process
if __name__ == "__main__":
    main()
